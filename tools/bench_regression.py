"""CI bench-regression smoke: paged-attention kernel vs jnp gather
(ISSUE 5 satellite), plus the fault-free monitoring-overhead bound of
the fault-tolerant serving runtime (ISSUE 6).

Runs the serve-bench paged-KV smoke serving configuration twice — once
with the fused Pallas paged-attention read path
(kernels/paged_attention.py), once with the jnp gather reference — and
asserts the matched-prefix logit RMSE between the two paths stays below
the checked-in threshold (tools/ci_thresholds.json), plus full token
agreement.  Kernel drift (a masking bug, a softmax-order change, a tile
regression) is caught here, in CI, instead of surfacing later as a
mysteriously-degraded BENCH row.

The comparison metric is launch/serve.py ``logit_drift_rmse`` — the same
teacher-matched-prefix RMSE serve_bench and the acceptance tests use, so
the threshold means the same thing everywhere.  Both paths run the same
f32 page walk in the same order, so the healthy RMSE is float-epsilon
noise (~1e-8 — XLA's einsum layout vs the kernel's dot_general round
differently); the 1e-5 threshold is the acceptance-criterion bound, two
decades above it.

The two paths are selected via ``serve_batch(paged_attn=...)`` — the
read-path pin is part of the jitted builder's cache key, so each run
traces its own executable.

The ISSUE 6 leg serves the same continuous queue plain and with the
accuracy watchdog + boundary snapshots armed (no faults injected) and
bounds the wall-time ratio at ``chaos_monitor_overhead_ratio``.  The CI
bound is deliberately looser than the <=5% the full-size BENCH row
demonstrates (BENCH_kernels.json ``serve/chaos_monitored``): the CI
shape is tiny (one probe, a couple of snapshots, ~100 ms of serving),
so runner timing noise dominates the true monitoring cost — the gate
exists to catch a monitoring path that suddenly costs a *multiple* of
serving (an accidental per-segment device sync, a probe that stopped
respecting its cadence), not to re-measure the 5%.

The ISSUE 9 leg serves the same queue with ``integrity='off'`` and
``integrity='scrub:2'`` (no faults injected) and bounds the wall-time
ratio at ``integrity_scrub_overhead_ratio``.  Like the monitoring bound,
the CI bound (1.15x) is looser than the checksum layer's true cost at
real shapes — digest upkeep rides the jitted write paths and the sweeps
are one compiled reduction per period — so the gate catches a scrubbing
path that suddenly costs a multiple of serving (a per-segment host
round-trip, a digest recompute that stopped being incremental), not the
percent-level truth the full-size ``serve/integrity_scrub`` BENCH row
records.

The ISSUE 7 leg serves the self-speculative greedy configuration
(dscim2:64 drafts, dscim1:256 verify, int8 paged KV) and gates two
things: the spec output must be *bitwise* the plain-driver output (the
tentpole acceptance criterion — any drift is an immediate fail, no
threshold), and the greedy acceptance rate (accepted draft tokens per
drafted token) must stay above ``spec_greedy_acceptance_rate_min``.
Both drivers are deterministic on the fixed seed, so the measured rate
(0.48 at the full CI shape, 0.58 at the smoke shape) is reproducible;
the 0.40 bound is measured-minus-slack — a drafter regression (wrong
draft cache, a desynced operating point, an estimator change that
silently decorrelates dscim2 from dscim1) shows up as a rate collapse
long before it shows up in tok/s.

The ISSUE 8 leg replays the mini router load test
(benchmarks/loadtest.py ``run_loadtest(smoke=True)`` — plain + sampled-
fault legs, every-request-terminates and zero-live-pages asserted inside)
and bounds two service-shaped regressions: the worst-leg p99/p50 latency
ratio at ``router_p99_p50_ratio_max`` (a head-of-line collapse — one
chunked admission or a failover replay stalling the whole decode plane —
shows up as p99 exploding while p50 stays flat; the bound is generous
because a single injected device-loss replay legitimately stretches the
chaos leg's tail at CI shapes) and the refusal rate at
``router_refusal_rate_max`` (admission control that starts refusing the
majority of a modest trace is broken backpressure, not load shedding).

The ISSUE 10 leg serves the 90%-shared-prefix request queue warm
(``prefix_cache='on'``) and cold (``prefix_cache='cold'`` — the
identical page-aligned chunked admission path with lookup/registration
disabled), asserts the warm outputs bitwise against the cold ones (the
tentpole acceptance criterion: a prefix hit maps page-table entries to
already-quantized physical pages, it never re-derives bytes), and
bounds two metrics: the fraction of prefill positions removed by page
sharing must stay above ``prefix_flops_removed_min`` (the >= 0.4
acceptance bar at the 90% trace; measured 0.50 at the CI shape — 5 of
6 requests share 3 of 4 prompt pages and only the first admission pays
for them), and the mean wall admission latency of a prefix *hit* over
the cold leg's miss admissions must stay below
``prefix_hit_admission_latency_ratio_max`` (hits feed strictly fewer
chunks through the same compiled extend program, so the ratio sits
well under 1 — 0.37 measured; a ratio drifting toward 1 means hit
admissions started re-feeding their shared pages, i.e. the dedup
stopped removing work without breaking bitwise parity).

Usage:  PYTHONPATH=src python -m tools.bench_regression [--smoke]
(--smoke shortens the trace; CI passes it.)  Exit 0 on pass, 1 on drift.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
THRESHOLDS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "ci_thresholds.json")


def _serve_both_paths(smoke: bool):
    """(tokens, trace) for the kernel and gather read paths on the
    serve-bench paged-KV smoke shape (float model — the read path is the
    only thing under test)."""
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.launch.serve import serve_batch
    from repro.models import get_model

    cfg = get_arch("qwen3-0.6b").reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len = 4, 16
    n_tokens = 16 if smoke else 48
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (B, prompt_len), dtype=np.int32)

    return {path: serve_batch(cfg, params, prompts, n_tokens,
                              trace_logits=True, prepare=False,
                              kv="int8", page_size=4, paged_attn=path)
            for path in ("kernel", "jnp")}


def _chaos_monitor_overhead(smoke: bool) -> float:
    """Fault-free wall-time ratio monitored/plain for serve_continuous on
    a small continuous queue (ISSUE 6).  Median of 3 warmed runs per path
    even in smoke — single-shot timings on a CI runner are too noisy to
    gate on — and the queue does NOT shrink under --smoke: below ~8
    decode segments the one probe + one snapshot are a fixed cost with
    nothing to amortize over and the ratio measures shape, not the
    monitoring path (measured: 1.35x at 3 segments vs ~1.0x at 8)."""
    import dataclasses

    import jax
    import numpy as np

    from benchmarks.common import timed
    from repro.configs import get_arch
    from repro.launch.serve import serve_continuous
    from repro.models import get_model
    from repro.runtime.serving import watchdog_for_spec

    spec = "kernel:dscim1:256"
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(), dscim=spec)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    R, prompt_len = 4, 8
    n_tokens = 8
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (R, prompt_len), dtype=np.int32)
    budgets = np.linspace(2, n_tokens, R).round().astype(np.int32)
    knobs = dict(slots=2, seg_len=4, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4)
    monitor = watchdog_for_spec(spec, probe_every=8)

    def plain():
        return serve_continuous(cfg, params, prompts, n_tokens, **knobs)[0]

    def monitored():
        return serve_continuous(cfg, params, prompts, n_tokens, **knobs,
                                monitor=monitor, snapshot_every=8)[0]

    us_plain = timed(plain, n=3)
    us_mon = timed(monitored, n=3)
    return us_mon / us_plain


def _integrity_overhead(smoke: bool) -> float:
    """Fault-free wall-time ratio scrub:2/off for serve_continuous on a
    small continuous queue (ISSUE 9).  Same shape discipline as
    ``_chaos_monitor_overhead`` (the queue does not shrink under --smoke
    — below ~8 decode segments the boundary sweeps are fixed cost with
    nothing to amortize over), but the estimator is min-of-5 over
    *interleaved* off/scrub reps rather than a median of 3: at ~200 ms a
    run, CI-runner noise spans tens of percent and an unpaired median
    ratio flaps; interleaved minima track the noise floor both legs
    share, which is the quantity the bound is about."""
    import dataclasses
    import time

    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.launch.serve import serve_continuous
    from repro.models import get_model

    spec = "kernel:dscim1:256"
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(), dscim=spec)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    R, prompt_len = 4, 8
    n_tokens = 8
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (R, prompt_len), dtype=np.int32)
    budgets = np.linspace(2, n_tokens, R).round().astype(np.int32)
    knobs = dict(slots=2, seg_len=4, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=4)

    def off():
        return serve_continuous(cfg, params, prompts, n_tokens, **knobs)[0]

    def scrubbed():
        return serve_continuous(cfg, params, prompts, n_tokens, **knobs,
                                integrity="scrub:2")[0]

    off(), scrubbed()  # warm both executables (trace + compile)
    best = {"off": float("inf"), "scrub": float("inf")}
    for _ in range(5):
        for name, fn in (("off", off), ("scrub", scrubbed)):
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best["scrub"] / best["off"]


def _spec_acceptance(smoke: bool):
    """(bitwise_match, acceptance_rate) for greedy self-speculative
    decoding on the serve-bench spec shape (ISSUE 7)."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.launch.serve import serve_batch
    from repro.models import get_model

    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              dscim="kernel:dscim1:256")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len, k = 4, 8, 4
    n_tokens = 8 if smoke else 16
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (B, prompt_len), dtype=np.int32)
    kw = dict(kv="int8", page_size=4)
    t_ref, _ = serve_batch(cfg, params, prompts, n_tokens, **kw)
    t_spec, _, ss = serve_batch(cfg, params, prompts, n_tokens,
                                spec=f"dscim2:{k}", spec_stats=True, **kw)
    match = bool(np.array_equal(np.asarray(t_spec), np.asarray(t_ref)))
    accepted = int((ss["emitted"] - 1).sum())
    rate = accepted / max(int(ss["windows"].sum()), 1) / k
    return match, rate


def _prefix_leg(smoke: bool):
    """(bitwise_match, prefill_removed_frac, admit_latency_ratio) for the
    90%-shared-prefix continuous-serving queue (ISSUE 10).  Both legs run
    the same compiled page-aligned chunked extend program; the cold leg
    just has prefix lookup/registration disabled, so the warm outputs
    must be token-identical and every difference is pure dedup.  Each leg
    runs twice and the second run's stats are used — the first pair warms
    the shared executables so the admission wall-clock samples measure
    the steady-state path, not tracing."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.launch.serve import serve_continuous
    from repro.models import get_model

    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              dscim="kernel:dscim1:256")
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    ps, S, R = 4, 16, 6
    n_tokens = 4 if smoke else 8
    rng = np.random.default_rng(0)
    budgets = np.clip(np.linspace(2, n_tokens, R).round(), 2,
                      n_tokens).astype(np.int32)
    prompts = rng.integers(0, cfg.vocab, (R, S), dtype=np.int32)
    prompts[:round(0.9 * R), :12] = rng.integers(0, cfg.vocab, 12,
                                                 dtype=np.int32)
    knobs = dict(slots=2, seg_len=2, max_new=budgets, eos_id=-1,
                 kv="int8", page_size=ps, prepare=False,
                 log=lambda *a: None)

    def leg(mode):
        return serve_continuous(cfg, params, prompts, n_tokens,
                                prefix_cache=mode, **knobs)

    leg("cold"), leg("on")          # warm the shared executables
    out_c, st_c = leg("cold")
    out_w, st_w = leg("on")
    match = len(out_c) == len(out_w) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(out_c, out_w))
    pw = st_w["prefix"]
    removed = 1.0 - pw["prefill_positions_computed"] \
        / max(pw["prefill_positions_total"], 1)
    lat_cold = float(np.mean(st_c["prefix"]["admit_lat_miss"]))
    lat_hit = float(np.mean(pw["admit_lat_hit"])) if pw["admit_lat_hit"] \
        else float("inf")
    return match, removed, lat_hit / max(lat_cold, 1e-12)


def _router_loadtest(smoke: bool):
    """(worst-leg p99/p50 ratio, worst-leg refusal rate) from the mini
    router load test (ISSUE 8).  run_loadtest itself hard-asserts the
    liveness contract (every request terminal, zero live pages at drain,
    ok-vs-ok bitwise agreement between legs); this leg adds the bounded
    service metrics on top."""
    sys.path.insert(0, REPO)        # benchmarks/ package, as CI runs it
    from benchmarks.loadtest import run_loadtest
    _, m_plain, m_chaos = run_loadtest(True, log=lambda *a: None)
    ratio = max(m["p99_ms"] / max(m["p50_ms"], 1e-9)
                for m in (m_plain, m_chaos))
    refusal = max(m["refusal_rate"] for m in (m_plain, m_chaos))
    return ratio, refusal


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace (the CI leg)")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.launch.serve import _agreement, logit_drift_rmse

    with open(THRESHOLDS) as f:
        th = json.load(f)
    out = _serve_both_paths(args.smoke)
    tk, lk = out["kernel"]
    tj, lj = out["jnp"]
    rmse = logit_drift_rmse(tj, tk, lj, lk)
    agree = _agreement(np.asarray(tk), np.asarray(tj), None)
    bound = th["paged_kernel_vs_gather_logit_rmse"]
    min_agree = th["paged_kernel_vs_gather_token_agreement"]
    print(f"paged kernel vs jnp gather: matched-prefix logit RMSE "
          f"{rmse:.3e} (threshold {bound:.0e}), token agreement "
          f"{agree:.4f} (threshold {min_agree})")
    ok = rmse <= bound and agree >= min_agree
    if not ok:
        print("BENCH REGRESSION: paged-attention kernel drifted from the "
              "jnp gather reference", file=sys.stderr)

    ratio = _chaos_monitor_overhead(args.smoke)
    ratio_bound = th["chaos_monitor_overhead_ratio"]
    print(f"fault-tolerant serving monitoring overhead: "
          f"{ratio:.3f}x plain (threshold {ratio_bound}x)")
    if ratio > ratio_bound:
        print("BENCH REGRESSION: fault-free monitoring overhead of the "
              "serving runtime exceeded its bound", file=sys.stderr)
        ok = False

    iratio = _integrity_overhead(args.smoke)
    iratio_bound = th["integrity_scrub_overhead_ratio"]
    print(f"integrity scrubbing overhead: {iratio:.3f}x off "
          f"(threshold {iratio_bound}x)")
    if iratio > iratio_bound:
        print("BENCH REGRESSION: fault-free integrity scrubbing overhead "
              "exceeded its bound", file=sys.stderr)
        ok = False

    match, rate = _spec_acceptance(args.smoke)
    rate_min = th["spec_greedy_acceptance_rate_min"]
    print(f"self-speculative greedy serving: bitwise match {match}, "
          f"acceptance rate {rate:.3f} (threshold {rate_min})")
    if not match:
        print("BENCH REGRESSION: greedy self-speculative output drifted "
              "from the plain driver (bitwise-parity contract)",
              file=sys.stderr)
        ok = False
    if rate < rate_min:
        print("BENCH REGRESSION: greedy self-spec acceptance rate "
              "collapsed below its bound", file=sys.stderr)
        ok = False

    pmatch, removed, admit_ratio = _prefix_leg(args.smoke)
    removed_min = th["prefix_flops_removed_min"]
    admit_max = th["prefix_hit_admission_latency_ratio_max"]
    print(f"prefix cache (90% shared trace): bitwise match {pmatch}, "
          f"prefill removed {removed:.3f} (threshold >= {removed_min}), "
          f"hit/cold admission latency ratio {admit_ratio:.3f} "
          f"(threshold <= {admit_max})")
    if not pmatch:
        print("BENCH REGRESSION: prefix-cached serving drifted from the "
              "cold chunked reference (bitwise-parity contract)",
              file=sys.stderr)
        ok = False
    if removed < removed_min:
        print("BENCH REGRESSION: prefix caching stopped removing prefill "
              "work — shared pages are being re-fed", file=sys.stderr)
        ok = False
    if admit_ratio > admit_max:
        print("BENCH REGRESSION: prefix-hit admission latency no longer "
              "beats a cold admission — dedup is not skipping chunks",
              file=sys.stderr)
        ok = False

    tail, refusal = _router_loadtest(args.smoke)
    tail_max = th["router_p99_p50_ratio_max"]
    refusal_max = th["router_refusal_rate_max"]
    print(f"router load test: p99/p50 ratio {tail:.2f} (threshold "
          f"{tail_max}), refusal rate {refusal:.3f} "
          f"(threshold {refusal_max})")
    if tail > tail_max:
        print("BENCH REGRESSION: router tail latency collapsed — p99/p50 "
              "exceeded its bound (head-of-line blocking?)",
              file=sys.stderr)
        ok = False
    if refusal > refusal_max:
        print("BENCH REGRESSION: router refusal rate exceeded its bound — "
              "admission control is shedding most of a modest trace",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
