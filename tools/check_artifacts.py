"""CI artifact gate: schema-validate the checked-in benchmark trajectory
and autotune cache (ISSUE 5 satellite).

Every PR appends to ``BENCH_kernels.json`` (benchmarks/run.py) and may
regenerate ``src/repro/kernels/autotune_cache.json``
(benchmarks/autotune_serving.py).  Both are load-bearing: serving reads
the autotune cache at cold start, and the bench trajectory is the perf
baseline future PRs diff against — a malformed append (truncated JSON,
a row missing its ``us`` field, a cache value with the wrong arity) would
poison them silently.  This gate fails the build instead.

Checks (no third-party deps — stdlib json only):

* BENCH_kernels.json: top-level ``{"runs": [...]}``; every run carries a
  well-formed git rev (short/long hex or the documented 'unknown'
  fallback), an ISO-ish timestamp, and a non-empty ``rows`` list whose
  rows each have a non-empty ``name`` (str), a finite positive ``us``
  (number) and a ``derived`` (str).
* autotune_cache.json: a flat ``{key: [ints]}`` dict; keys must parse as
  a known kernel kind (``fused/`` / ``mvm/`` / ``paged_attn/``) ending in
  a cpu|tpu backend segment, and values must be positive-int tuples of
  that kind's arity (fused (bm, bn, bk) = 3, mvm (bm, bn, bk, bl) = 4,
  paged_attn (gh, qp) = 2).
* serve/chaos_* rows (ISSUE 6): the fault-tolerance bench rows carry a
  typed derived contract — chaos_plain/chaos_monitored need a finite
  positive ``tok_s``; chaos_monitored additionally needs a positive
  ``overhead_vs_plain`` ratio (the CI-bounded fault-free monitoring
  cost); chaos_drill needs its scenario counters (``requests``,
  ``replays``, ``probe_trips``, ``escalations``, ``deadline_cancelled``)
  as non-negative ints.  A chaos row whose derived fields went missing
  or non-numeric would silently blind the regression gate.

Usage:  python tools/check_artifacts.py [--bench PATH] [--cache PATH]
Exit 0 on pass; exit 1 with one line per violation on failure.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DEFAULT = os.path.join(REPO, "BENCH_kernels.json")
CACHE_DEFAULT = os.path.join(REPO, "src", "repro", "kernels",
                             "autotune_cache.json")

_REV_RE = re.compile(r"^([0-9a-f]{7,40}|unknown)$")
_TS_RE = re.compile(r"^\d{4}-\d{2}-\d{2}T\d{2}:\d{2}:\d{2}$")
_ARITY = {"fused": 3, "mvm": 4, "paged_attn": 2}


def _derived_fields(derived: str) -> dict:
    """Parse the ``k=v;k=v`` derived string (values stay strings)."""
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def _pos_float(v) -> bool:
    try:
        x = float(v)
    except (TypeError, ValueError):
        return False
    return x > 0 and x == x and x != float("inf")


def _nonneg_int(v) -> bool:
    try:
        return int(v) >= 0 and float(v) == int(v)
    except (TypeError, ValueError):
        return False


def _check_chaos_row(name: str, derived: str, rtag: str, errs: list):
    """ISSUE 6: typed schema for serve/chaos_* derived fields."""
    f = _derived_fields(derived)
    kind = name.split("/", 2)[1]            # chaos_plain | _monitored | _drill
    if kind in ("chaos_plain", "chaos_monitored"):
        if not _pos_float(f.get("tok_s")):
            errs.append(f"{rtag} ({name!r}): chaos row needs a finite "
                        f"positive tok_s, got {f.get('tok_s')!r}")
    if kind == "chaos_monitored":
        if not _pos_float(f.get("overhead_vs_plain")):
            errs.append(f"{rtag} ({name!r}): chaos_monitored needs a "
                        f"positive overhead_vs_plain ratio, got "
                        f"{f.get('overhead_vs_plain')!r}")
    if kind == "chaos_drill":
        for key in ("requests", "replays", "probe_trips", "escalations",
                    "deadline_cancelled"):
            if not _nonneg_int(f.get(key)):
                errs.append(f"{rtag} ({name!r}): chaos_drill needs "
                            f"non-negative int {key}, got {f.get(key)!r}")


def _load(path: str, errs: list) -> object | None:
    if not os.path.exists(path):
        errs.append(f"{path}: missing")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except ValueError as e:
        errs.append(f"{path}: not valid JSON ({e})")
        return None


def check_bench(path: str) -> list:
    errs: list = []
    data = _load(path, errs)
    if data is None:
        return errs
    if not isinstance(data, dict) or not isinstance(data.get("runs"), list):
        return [f"{path}: top level must be {{'runs': [...]}}"]
    for i, run in enumerate(data["runs"]):
        tag = f"{path}: runs[{i}]"
        if not isinstance(run, dict):
            errs.append(f"{tag}: not an object")
            continue
        rev = run.get("rev")
        if not (isinstance(rev, str) and _REV_RE.match(rev)):
            errs.append(f"{tag}: bad rev {rev!r}")
        ts = run.get("ts")
        if not (isinstance(ts, str) and _TS_RE.match(ts)):
            errs.append(f"{tag}: bad ts {ts!r}")
        rows = run.get("rows")
        if not (isinstance(rows, list) and rows):
            errs.append(f"{tag}: rows must be a non-empty list")
            continue
        for j, row in enumerate(rows):
            rtag = f"{tag}.rows[{j}]"
            if not isinstance(row, dict):
                errs.append(f"{rtag}: not an object")
                continue
            name = row.get("name")
            if not (isinstance(name, str) and name.strip()):
                errs.append(f"{rtag}: bad name {name!r}")
            us = row.get("us")
            if not (isinstance(us, (int, float)) and not isinstance(us, bool)
                    and us > 0 and us == us and us != float("inf")):
                errs.append(f"{rtag} ({name!r}): bad us {us!r}")
            derived = row.get("derived")
            if not isinstance(derived, str):
                errs.append(f"{rtag} ({name!r}): bad derived {derived!r}")
            elif isinstance(name, str) and name.startswith("serve/chaos_"):
                _check_chaos_row(name, derived, rtag, errs)
    return errs


def check_cache(path: str) -> list:
    errs: list = []
    data = _load(path, errs)
    if data is None:
        return errs
    if not isinstance(data, dict):
        return [f"{path}: top level must be an object"]
    for key, val in data.items():
        tag = f"{path}: {key!r}"
        kind = str(key).split("/", 1)[0]
        if kind not in _ARITY:
            errs.append(f"{tag}: unknown kernel kind {kind!r} "
                        f"(want one of {sorted(_ARITY)})")
            continue
        if str(key).rsplit("/", 1)[-1] not in ("cpu", "tpu"):
            errs.append(f"{tag}: key must end in a cpu|tpu backend segment")
        if not (isinstance(val, list)
                and len(val) == _ARITY[kind]
                and all(isinstance(v, int) and not isinstance(v, bool)
                        and v > 0 for v in val)):
            errs.append(f"{tag}: value {val!r} must be {_ARITY[kind]} "
                        "positive ints")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=BENCH_DEFAULT)
    ap.add_argument("--cache", default=CACHE_DEFAULT)
    args = ap.parse_args(argv)
    errs = check_bench(args.bench) + check_cache(args.cache)
    for e in errs:
        print(f"ARTIFACT ERROR: {e}", file=sys.stderr)
    if not errs:
        print(f"artifacts OK: {args.bench}, {args.cache}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
