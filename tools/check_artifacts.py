"""CI artifact gate: schema-validate the checked-in benchmark trajectory
and autotune cache (ISSUE 5 satellite).

Every PR appends to ``BENCH_kernels.json`` (benchmarks/run.py) and may
regenerate ``src/repro/kernels/autotune_cache.json``
(benchmarks/autotune_serving.py).  Both are load-bearing: serving reads
the autotune cache at cold start, and the bench trajectory is the perf
baseline future PRs diff against — a malformed append (truncated JSON,
a row missing its ``us`` field, a cache value with the wrong arity) would
poison them silently.  This gate fails the build instead.

Checks (no third-party deps — stdlib json only):

* BENCH_kernels.json: top-level ``{"runs": [...]}``; every run carries a
  well-formed git rev (short/long hex or the documented 'unknown'
  fallback), an ISO-ish timestamp, and a non-empty ``rows`` list whose
  rows each have a non-empty ``name`` (str), a finite positive ``us``
  (number) and a ``derived`` (str).
* autotune_cache.json: a flat ``{key: [ints]}`` dict; keys must parse as
  a known kernel kind (``fused/`` / ``mvm/`` / ``paged_attn/``) ending in
  a cpu|tpu backend segment, and values must be positive-int tuples of
  that kind's arity (fused (bm, bn, bk) = 3, mvm (bm, bn, bk, bl) = 4,
  paged_attn (gh, qp) = 2).

Usage:  python tools/check_artifacts.py [--bench PATH] [--cache PATH]
Exit 0 on pass; exit 1 with one line per violation on failure.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DEFAULT = os.path.join(REPO, "BENCH_kernels.json")
CACHE_DEFAULT = os.path.join(REPO, "src", "repro", "kernels",
                             "autotune_cache.json")

_REV_RE = re.compile(r"^([0-9a-f]{7,40}|unknown)$")
_TS_RE = re.compile(r"^\d{4}-\d{2}-\d{2}T\d{2}:\d{2}:\d{2}$")
_ARITY = {"fused": 3, "mvm": 4, "paged_attn": 2}


def _load(path: str, errs: list) -> object | None:
    if not os.path.exists(path):
        errs.append(f"{path}: missing")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except ValueError as e:
        errs.append(f"{path}: not valid JSON ({e})")
        return None


def check_bench(path: str) -> list:
    errs: list = []
    data = _load(path, errs)
    if data is None:
        return errs
    if not isinstance(data, dict) or not isinstance(data.get("runs"), list):
        return [f"{path}: top level must be {{'runs': [...]}}"]
    for i, run in enumerate(data["runs"]):
        tag = f"{path}: runs[{i}]"
        if not isinstance(run, dict):
            errs.append(f"{tag}: not an object")
            continue
        rev = run.get("rev")
        if not (isinstance(rev, str) and _REV_RE.match(rev)):
            errs.append(f"{tag}: bad rev {rev!r}")
        ts = run.get("ts")
        if not (isinstance(ts, str) and _TS_RE.match(ts)):
            errs.append(f"{tag}: bad ts {ts!r}")
        rows = run.get("rows")
        if not (isinstance(rows, list) and rows):
            errs.append(f"{tag}: rows must be a non-empty list")
            continue
        for j, row in enumerate(rows):
            rtag = f"{tag}.rows[{j}]"
            if not isinstance(row, dict):
                errs.append(f"{rtag}: not an object")
                continue
            name = row.get("name")
            if not (isinstance(name, str) and name.strip()):
                errs.append(f"{rtag}: bad name {name!r}")
            us = row.get("us")
            if not (isinstance(us, (int, float)) and not isinstance(us, bool)
                    and us > 0 and us == us and us != float("inf")):
                errs.append(f"{rtag} ({name!r}): bad us {us!r}")
            if not isinstance(row.get("derived"), str):
                errs.append(f"{rtag} ({name!r}): bad derived "
                            f"{row.get('derived')!r}")
    return errs


def check_cache(path: str) -> list:
    errs: list = []
    data = _load(path, errs)
    if data is None:
        return errs
    if not isinstance(data, dict):
        return [f"{path}: top level must be an object"]
    for key, val in data.items():
        tag = f"{path}: {key!r}"
        kind = str(key).split("/", 1)[0]
        if kind not in _ARITY:
            errs.append(f"{tag}: unknown kernel kind {kind!r} "
                        f"(want one of {sorted(_ARITY)})")
            continue
        if str(key).rsplit("/", 1)[-1] not in ("cpu", "tpu"):
            errs.append(f"{tag}: key must end in a cpu|tpu backend segment")
        if not (isinstance(val, list)
                and len(val) == _ARITY[kind]
                and all(isinstance(v, int) and not isinstance(v, bool)
                        and v > 0 for v in val)):
            errs.append(f"{tag}: value {val!r} must be {_ARITY[kind]} "
                        "positive ints")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=BENCH_DEFAULT)
    ap.add_argument("--cache", default=CACHE_DEFAULT)
    args = ap.parse_args(argv)
    errs = check_bench(args.bench) + check_cache(args.cache)
    for e in errs:
        print(f"ARTIFACT ERROR: {e}", file=sys.stderr)
    if not errs:
        print(f"artifacts OK: {args.bench}, {args.cache}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
