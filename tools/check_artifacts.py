"""CI artifact gate: schema-validate the checked-in benchmark trajectory
and autotune cache (ISSUE 5 satellite).

Every PR appends to ``BENCH_kernels.json`` (benchmarks/run.py) and may
regenerate ``src/repro/kernels/autotune_cache.json``
(benchmarks/autotune_serving.py).  Both are load-bearing: serving reads
the autotune cache at cold start, and the bench trajectory is the perf
baseline future PRs diff against — a malformed append (truncated JSON,
a row missing its ``us`` field, a cache value with the wrong arity) would
poison them silently.  This gate fails the build instead.

Checks (no third-party deps — stdlib json only):

* BENCH_kernels.json: top-level ``{"runs": [...]}``; every run carries a
  well-formed git rev (short/long hex or the documented 'unknown'
  fallback), an ISO-ish timestamp, and a non-empty ``rows`` list whose
  rows each have a non-empty ``name`` (str), a finite positive ``us``
  (number) and a ``derived`` (str).
* autotune_cache.json: a flat ``{key: [ints]}`` dict; keys must parse as
  a known kernel kind (``fused/`` / ``mvm/`` / ``paged_attn/``) ending in
  a cpu|tpu backend segment, and values must be positive-int tuples of
  that kind's arity (fused (bm, bn, bk) = 3, mvm (bm, bn, bk, bl) = 4,
  paged_attn (gh, qp) = 2).
* serve/chaos_* rows (ISSUE 6): the fault-tolerance bench rows carry a
  typed derived contract — chaos_plain/chaos_monitored need a finite
  positive ``tok_s``; chaos_monitored additionally needs a positive
  ``overhead_vs_plain`` ratio (the CI-bounded fault-free monitoring
  cost) and the PageAllocator occupancy counters (``pages_live`` — zero
  at end of serve, a leak otherwise — ``pages_high_water``,
  ``pages_refusals``; pre-ISSUE-7 rows without any of them are
  grandfathered, malformed values are not); chaos_drill needs its
  scenario counters
  (``requests``, ``replays``, ``probe_trips``, ``escalations``,
  ``deadline_cancelled``) as non-negative ints.  A chaos row whose
  derived fields went missing or non-numeric would silently blind the
  regression gate.
* serve/spec_* rows (ISSUE 7): the self-speculative decoding rows need a
  finite positive ``tok_s``; the drafted rows (spec_dscim*) additionally
  need ``accepted_tok_per_verify`` (positive), ``acceptance_rate`` in
  (0, 1], and ``tokens_match=1`` — the bitwise-parity assertion baked
  into the bench; spec_continuous rows carry the allocator counters
  (``pages_live``/``pages_high_water``/``pages_refusals``) like
  chaos_monitored.
* serve/router_* rows (ISSUE 8): the async-router load-test rows
  (benchmarks/loadtest.py) need the latency percentiles
  (``p50_ms``/``p99_ms``) and ``tok_s`` finite positive,
  ``refusal_rate`` in [0, 1], the status ledger (``ok``/``deadline``/
  ``refused``/``cancelled``/``degraded``) as non-negative ints summing
  to ``requests`` (the every-request-terminates contract, checked at
  rest), ``replays``/``quarantined`` counters, and the allocator
  counters with ``pages_live=0`` — router rows are recorded after
  drain, so any live page is a leak.
* serve/integrity_* rows (ISSUE 9): the checksummed-state integrity rows
  need a finite positive ``tok_s``; integrity_scrub additionally needs a
  positive ``overhead_vs_off`` ratio (the CI-bounded scrubbing cost) and
  the sweep coverage/repair counters as non-negative ints;
  integrity_drill needs its repair/replay counters.
* serve/prefix_* rows (ISSUE 10): the prefix-cache rows carry the dedup
  ledger (``hits``/``lookups``/``hit_tokens``/``pages_deduped`` as
  non-negative ints) and ``prefill_removed_frac`` in [0, 1] — the
  CI-bounded fraction of prefill positions never computed because their
  pages were shared.  The serve-bench hit-rate sweep rows
  (prefix_hit0/hit50/hit90) additionally need a finite positive
  ``tok_s``, ``hit_rate_target`` in [0, 1], a positive
  ``admit_latency_ratio`` (hit-vs-cold admission wall time, CI-bounded
  in tools/bench_regression.py), and drained allocator occupancy
  (``pages_live=0``, ``pages_retained``/``pages_shares`` non-negative —
  retained pages are the prefix index's parked ref-0 pages, not leaks).
  The router trace row (prefix_router) rides the full serve/router_*
  schema (latency percentiles, terminal-status ledger, zero live pages)
  plus a non-negative ``bitwise_ok`` count — the number of ok-vs-ok
  request pairs asserted token-identical between the warm and cold legs.
* No duplicate rows (ISSUE 7 satellite): a row name may appear at most
  once per run, and a (name, rev) pair at most once across the whole
  trajectory — benchmarks/run.py dedupes on append (newest run wins), so
  a duplicate here means someone bypassed it and the perf diff would
  silently average two measurements.

Usage:  python tools/check_artifacts.py [--bench PATH] [--cache PATH]
Exit 0 on pass; exit 1 with one line per violation on failure.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DEFAULT = os.path.join(REPO, "BENCH_kernels.json")
CACHE_DEFAULT = os.path.join(REPO, "src", "repro", "kernels",
                             "autotune_cache.json")

_REV_RE = re.compile(r"^([0-9a-f]{7,40}|unknown)$")
_TS_RE = re.compile(r"^\d{4}-\d{2}-\d{2}T\d{2}:\d{2}:\d{2}$")
_ARITY = {"fused": 3, "mvm": 4, "paged_attn": 2}


def _derived_fields(derived: str) -> dict:
    """Parse the ``k=v;k=v`` derived string (values stay strings)."""
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def _pos_float(v) -> bool:
    try:
        x = float(v)
    except (TypeError, ValueError):
        return False
    return x > 0 and x == x and x != float("inf")


def _nonneg_int(v) -> bool:
    try:
        return int(v) >= 0 and float(v) == int(v)
    except (TypeError, ValueError):
        return False


def _check_chaos_row(name: str, derived: str, rtag: str, errs: list):
    """ISSUE 6: typed schema for serve/chaos_* derived fields."""
    f = _derived_fields(derived)
    kind = name.split("/", 2)[1]            # chaos_plain | _monitored | _drill
    if kind in ("chaos_plain", "chaos_monitored"):
        if not _pos_float(f.get("tok_s")):
            errs.append(f"{rtag} ({name!r}): chaos row needs a finite "
                        f"positive tok_s, got {f.get('tok_s')!r}")
    if kind == "chaos_monitored":
        if not _pos_float(f.get("overhead_vs_plain")):
            errs.append(f"{rtag} ({name!r}): chaos_monitored needs a "
                        f"positive overhead_vs_plain ratio, got "
                        f"{f.get('overhead_vs_plain')!r}")
        _check_page_stats(name, f, rtag, errs, required=False)
    if kind == "chaos_drill":
        for key in ("requests", "replays", "probe_trips", "escalations",
                    "deadline_cancelled"):
            if not _nonneg_int(f.get(key)):
                errs.append(f"{rtag} ({name!r}): chaos_drill needs "
                            f"non-negative int {key}, got {f.get(key)!r}")


def _check_page_stats(name: str, f: dict, rtag: str, errs: list,
                      required: bool = True):
    """PageAllocator.stats() counters on continuous-serving rows.
    ``required=False`` grandfathers pre-ISSUE-7 rows that predate the
    counters: absent is tolerated, present-but-malformed is not."""
    keys = ("pages_live", "pages_high_water", "pages_refusals")
    if not required and not any(k in f for k in keys):
        return
    for key in keys:
        if not _nonneg_int(f.get(key)):
            errs.append(f"{rtag} ({name!r}): needs non-negative int "
                        f"{key} (PageAllocator.stats()), got "
                        f"{f.get(key)!r}")


def _check_spec_row(name: str, derived: str, rtag: str, errs: list):
    """ISSUE 7: typed schema for serve/spec_* derived fields."""
    f = _derived_fields(derived)
    kind = name.split("/", 2)[1]     # spec_off | spec_dscim2_k<k> | spec_...
    if not _pos_float(f.get("tok_s")):
        errs.append(f"{rtag} ({name!r}): spec row needs a finite positive "
                    f"tok_s, got {f.get('tok_s')!r}")
    if kind.startswith("spec_dscim"):
        if not _pos_float(f.get("accepted_tok_per_verify")):
            errs.append(f"{rtag} ({name!r}): drafted spec row needs a "
                        f"positive accepted_tok_per_verify, got "
                        f"{f.get('accepted_tok_per_verify')!r}")
        try:
            rate = float(f.get("acceptance_rate"))
        except (TypeError, ValueError):
            rate = -1.0
        if not 0.0 < rate <= 1.0:
            errs.append(f"{rtag} ({name!r}): acceptance_rate must be in "
                        f"(0, 1], got {f.get('acceptance_rate')!r}")
        if f.get("tokens_match") != "1":
            errs.append(f"{rtag} ({name!r}): drafted spec row must assert "
                        f"bitwise parity (tokens_match=1), got "
                        f"{f.get('tokens_match')!r}")
    if kind == "spec_continuous":
        _check_page_stats(name, f, rtag, errs)


def _check_integrity_row(name: str, derived: str, rtag: str, errs: list):
    """ISSUE 9: typed schema for serve/integrity_* derived fields
    (benchmarks/serve_bench.py ``_integrity_rows``).  integrity_off /
    integrity_scrub need a finite positive ``tok_s``; integrity_scrub
    additionally needs a positive ``overhead_vs_off`` ratio (the
    CI-bounded scrubbing cost) and the sweep coverage counters;
    integrity_drill needs its repair/replay counters — a drill row whose
    counters went missing would silently blind the self-healing gate."""
    f = _derived_fields(derived)
    kind = name.split("/", 2)[1]   # integrity_off | _scrub | _drill
    if kind in ("integrity_off", "integrity_scrub"):
        if not _pos_float(f.get("tok_s")):
            errs.append(f"{rtag} ({name!r}): integrity row needs a finite "
                        f"positive tok_s, got {f.get('tok_s')!r}")
    if kind == "integrity_scrub":
        if not _pos_float(f.get("overhead_vs_off")):
            errs.append(f"{rtag} ({name!r}): integrity_scrub needs a "
                        f"positive overhead_vs_off ratio, got "
                        f"{f.get('overhead_vs_off')!r}")
        for key in ("checks", "pages_verified", "weight_planes_verified",
                    "mismatches", "repairs"):
            if not _nonneg_int(f.get(key)):
                errs.append(f"{rtag} ({name!r}): integrity_scrub needs "
                            f"non-negative int {key}, got {f.get(key)!r}")
    if kind == "integrity_drill":
        for key in ("requests", "page_repairs", "weight_repairs",
                    "replays", "checks"):
            if not _nonneg_int(f.get(key)):
                errs.append(f"{rtag} ({name!r}): integrity_drill needs "
                            f"non-negative int {key}, got {f.get(key)!r}")


def _check_prefix_row(name: str, derived: str, rtag: str, errs: list):
    """ISSUE 10: typed schema for serve/prefix_* derived fields.  All
    prefix rows carry the dedup ledger and the removed-prefill fraction;
    the serve-bench sweep rows (prefix_hit*) add the admission-latency
    ratio and drained allocator occupancy, and the loadtest row
    (prefix_router) layers the prefix ledger on the full router-row
    schema plus the bitwise ok-vs-ok assertion count.  A prefix row
    whose ledger went missing would blind both CI bounds (the
    flops-removed floor and the hit-admission latency ceiling)."""
    f = _derived_fields(derived)
    kind = name.split("/", 2)[1]    # prefix_hit0|hit50|hit90|router
    for key in ("hits", "lookups", "hit_tokens", "pages_deduped"):
        if not _nonneg_int(f.get(key)):
            errs.append(f"{rtag} ({name!r}): prefix row needs non-negative "
                        f"int {key}, got {f.get(key)!r}")
    try:
        removed = float(f.get("prefill_removed_frac"))
    except (TypeError, ValueError):
        removed = -1.0
    if not 0.0 <= removed <= 1.0:
        errs.append(f"{rtag} ({name!r}): prefill_removed_frac must be in "
                    f"[0, 1], got {f.get('prefill_removed_frac')!r}")
    if kind == "prefix_router":
        _check_router_row(name, derived, rtag, errs)
        if not _nonneg_int(f.get("bitwise_ok")):
            errs.append(f"{rtag} ({name!r}): prefix_router needs a "
                        f"non-negative int bitwise_ok (ok-vs-ok pairs "
                        f"asserted token-identical), got "
                        f"{f.get('bitwise_ok')!r}")
        if not _nonneg_int(f.get("pages_retained")):
            errs.append(f"{rtag} ({name!r}): prefix_router needs "
                        f"non-negative int pages_retained, got "
                        f"{f.get('pages_retained')!r}")
    else:
        if not _pos_float(f.get("tok_s")):
            errs.append(f"{rtag} ({name!r}): prefix row needs a finite "
                        f"positive tok_s, got {f.get('tok_s')!r}")
        try:
            target = float(f.get("hit_rate_target"))
        except (TypeError, ValueError):
            target = -1.0
        if not 0.0 <= target <= 1.0:
            errs.append(f"{rtag} ({name!r}): hit_rate_target must be in "
                        f"[0, 1], got {f.get('hit_rate_target')!r}")
        if not _pos_float(f.get("admit_latency_ratio")):
            errs.append(f"{rtag} ({name!r}): prefix sweep row needs a "
                        f"positive admit_latency_ratio, got "
                        f"{f.get('admit_latency_ratio')!r}")
        for key in ("pages_retained", "pages_shares"):
            if not _nonneg_int(f.get(key)):
                errs.append(f"{rtag} ({name!r}): prefix sweep row needs "
                            f"non-negative int {key}, got {f.get(key)!r}")
        if f.get("pages_live") != "0":
            errs.append(f"{rtag} ({name!r}): prefix rows are recorded "
                        f"after drain — pages_live must be 0, got "
                        f"{f.get('pages_live')!r} (page leak)")


def _check_router_row(name: str, derived: str, rtag: str, errs: list):
    """ISSUE 8: typed schema for serve/router_* load-test rows
    (benchmarks/loadtest.py).  Every row must carry the latency
    percentiles, throughput, a refusal rate in [0, 1], the request/status
    ledger (statuses summing to requests — a request that vanished
    without a terminal status would break the sum), and drained page-pool
    counters with pages_live == 0."""
    f = _derived_fields(derived)
    for key in ("p50_ms", "p99_ms", "tok_s"):
        if not _pos_float(f.get(key)):
            errs.append(f"{rtag} ({name!r}): router row needs a finite "
                        f"positive {key}, got {f.get(key)!r}")
    try:
        rate = float(f.get("refusal_rate"))
    except (TypeError, ValueError):
        rate = -1.0
    if not 0.0 <= rate <= 1.0:
        errs.append(f"{rtag} ({name!r}): refusal_rate must be in [0, 1], "
                    f"got {f.get('refusal_rate')!r}")
    statuses = ("ok", "deadline", "refused", "cancelled", "degraded")
    for key in ("requests", "replays", "quarantined") + statuses:
        if not _nonneg_int(f.get(key)):
            errs.append(f"{rtag} ({name!r}): router row needs non-negative "
                        f"int {key}, got {f.get(key)!r}")
    try:
        if sum(int(f[s]) for s in statuses) != int(f["requests"]):
            errs.append(f"{rtag} ({name!r}): terminal statuses must sum to "
                        f"requests (every request ends definitely), got "
                        + ";".join(f"{s}={f[s]}" for s in statuses)
                        + f" vs requests={f['requests']}")
    except (KeyError, TypeError, ValueError):
        pass                        # already reported above
    _check_page_stats(name, f, rtag, errs)
    if f.get("pages_live") not in (None, "0"):
        errs.append(f"{rtag} ({name!r}): router rows are recorded after "
                    f"drain — pages_live must be 0, got "
                    f"{f.get('pages_live')!r} (page leak)")


def _load(path: str, errs: list) -> object | None:
    if not os.path.exists(path):
        errs.append(f"{path}: missing")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except ValueError as e:
        errs.append(f"{path}: not valid JSON ({e})")
        return None


def check_bench(path: str) -> list:
    errs: list = []
    data = _load(path, errs)
    if data is None:
        return errs
    if not isinstance(data, dict) or not isinstance(data.get("runs"), list):
        return [f"{path}: top level must be {{'runs': [...]}}"]
    seen_rev_name: dict = {}
    for i, run in enumerate(data["runs"]):
        tag = f"{path}: runs[{i}]"
        if not isinstance(run, dict):
            errs.append(f"{tag}: not an object")
            continue
        rev = run.get("rev")
        if not (isinstance(rev, str) and _REV_RE.match(rev)):
            errs.append(f"{tag}: bad rev {rev!r}")
        ts = run.get("ts")
        if not (isinstance(ts, str) and _TS_RE.match(ts)):
            errs.append(f"{tag}: bad ts {ts!r}")
        rows = run.get("rows")
        if not (isinstance(rows, list) and rows):
            errs.append(f"{tag}: rows must be a non-empty list")
            continue
        in_run: set = set()
        for j, row in enumerate(rows):
            rtag = f"{tag}.rows[{j}]"
            if not isinstance(row, dict):
                errs.append(f"{rtag}: not an object")
                continue
            name = row.get("name")
            if not (isinstance(name, str) and name.strip()):
                errs.append(f"{rtag}: bad name {name!r}")
            elif name in in_run:
                errs.append(f"{rtag}: duplicate row {name!r} within the run")
            else:
                in_run.add(name)
                key = (run.get("rev"), name)
                if key in seen_rev_name:
                    errs.append(f"{rtag}: duplicate (name, rev) "
                                f"({name!r}, {run.get('rev')!r}) — already "
                                f"in {seen_rev_name[key]}; "
                                "benchmarks/run.py dedupes on append")
                else:
                    seen_rev_name[key] = tag
            us = row.get("us")
            if not (isinstance(us, (int, float)) and not isinstance(us, bool)
                    and us > 0 and us == us and us != float("inf")):
                errs.append(f"{rtag} ({name!r}): bad us {us!r}")
            derived = row.get("derived")
            if not isinstance(derived, str):
                errs.append(f"{rtag} ({name!r}): bad derived {derived!r}")
            elif isinstance(name, str) and name.startswith("serve/chaos_"):
                _check_chaos_row(name, derived, rtag, errs)
            elif isinstance(name, str) and name.startswith("serve/spec_"):
                _check_spec_row(name, derived, rtag, errs)
            elif isinstance(name, str) and name.startswith("serve/router_"):
                _check_router_row(name, derived, rtag, errs)
            elif isinstance(name, str) and name.startswith("serve/prefix_"):
                _check_prefix_row(name, derived, rtag, errs)
            elif isinstance(name, str) \
                    and name.startswith("serve/integrity_"):
                _check_integrity_row(name, derived, rtag, errs)
    return errs


def check_cache(path: str) -> list:
    errs: list = []
    data = _load(path, errs)
    if data is None:
        return errs
    if not isinstance(data, dict):
        return [f"{path}: top level must be an object"]
    for key, val in data.items():
        tag = f"{path}: {key!r}"
        kind = str(key).split("/", 1)[0]
        if kind not in _ARITY:
            errs.append(f"{tag}: unknown kernel kind {kind!r} "
                        f"(want one of {sorted(_ARITY)})")
            continue
        if str(key).rsplit("/", 1)[-1] not in ("cpu", "tpu"):
            errs.append(f"{tag}: key must end in a cpu|tpu backend segment")
        if not (isinstance(val, list)
                and len(val) == _ARITY[kind]
                and all(isinstance(v, int) and not isinstance(v, bool)
                        and v > 0 for v in val)):
            errs.append(f"{tag}: value {val!r} must be {_ARITY[kind]} "
                        "positive ints")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=BENCH_DEFAULT)
    ap.add_argument("--cache", default=CACHE_DEFAULT)
    args = ap.parse_args(argv)
    errs = check_bench(args.bench) + check_cache(args.cache)
    for e in errs:
        print(f"ARTIFACT ERROR: {e}", file=sys.stderr)
    if not errs:
        print(f"artifacts OK: {args.bench}, {args.cache}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
