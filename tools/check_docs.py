"""CI docs gate (ISSUE 10 satellite): fail the build on broken relative
links or heading anchors in README.md and docs/*.md.

The docs pass (README, docs/architecture.md, docs/serving.md) leans hard
on cross-references — "see docs/serving.md#prefix-cache", "the contract
lives in ROADMAP.md" — and those rot silently: a renamed file or a
reworded heading leaves a dead link that nobody notices until an
operator follows it.  This gate walks every markdown link in the doc
set and checks, for relative targets, that the target file exists and
(when the link carries a ``#fragment`` into a markdown file) that the
fragment matches a real heading's GitHub-style anchor slug.

Scope (deliberately narrow — stdlib only, no markdown parser):

* Inline links/images ``[text](target)`` outside fenced code blocks.
  Reference-style definitions ``[label]: target`` are checked too.
* ``http(s)://`` / ``mailto:`` targets are skipped — CI must not
  depend on the network.
* Anchors are slugified the way GitHub renders headings: lowercase,
  markdown/code-span markup stripped, punctuation dropped, spaces to
  hyphens, ``-N`` suffixes for duplicates.
* Anchor checks only apply to ``.md`` targets (including self-links
  like ``(#section)``); fragments into source files (GitHub line
  anchors like ``#L10``) are existence-checked only.

Usage:  python tools/check_docs.py [FILES...]
(default: README.md and docs/*.md under the repo root)
Exit 0 on pass; exit 1 with one line per broken link.
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)<>\s]+)>?(?:\s+\"[^\"]*\")?\s*\)")
_REFDEF_RE = re.compile(r"^\s{0,3}\[[^\]]+\]:\s+<?(\S+?)>?\s*$")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.+?)\s*#*\s*$")
_FENCE_RE = re.compile(r"^\s*(```|~~~)")


def _slugify(text: str) -> str:
    """GitHub's heading-anchor slug: markup stripped, lowercased,
    non-word punctuation dropped, spaces hyphenated."""
    text = re.sub(r"`([^`]*)`", r"\1", text)              # code spans
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # link text
    text = re.sub(r"[*_]", "", text)                      # emphasis
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _iter_md_lines(path: str):
    """(lineno, line) pairs with fenced code blocks blanked out — links
    and headings inside ``` fences are examples, not references."""
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if _FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if not in_fence:
                yield i, line.rstrip("\n")


def heading_anchors(path: str) -> set:
    """All valid anchor slugs in a markdown file, with GitHub's ``-N``
    duplicate suffixing."""
    counts: dict = {}
    anchors: set = set()
    for _, line in _iter_md_lines(path):
        m = _HEADING_RE.match(line)
        if not m:
            continue
        slug = _slugify(m.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def _targets(path: str):
    """(lineno, target) for every checkable link target in the file."""
    for i, line in _iter_md_lines(path):
        # blank inline code spans so `[x](y)` examples aren't links
        clean = re.sub(r"`[^`]*`", "", line)
        for m in _LINK_RE.finditer(clean):
            yield i, m.group(1)
        m = _REFDEF_RE.match(clean)
        if m:
            yield i, m.group(1)


def check_file(path: str, anchor_cache: dict) -> list:
    errs = []
    base = os.path.dirname(os.path.abspath(path))
    rel = os.path.relpath(path, REPO)
    for lineno, target in _targets(path):
        if re.match(r"^[a-zA-Z][a-zA-Z0-9+.-]*:", target):
            continue                     # http(s)/mailto/etc — skip
        frag = ""
        if "#" in target:
            target, _, frag = target.partition("#")
        if target:
            dest = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(dest):
                errs.append(f"{rel}:{lineno}: broken link — "
                            f"{target!r} does not exist")
                continue
        else:
            dest = os.path.abspath(path)  # pure-fragment self link
        if frag and dest.endswith(".md") and os.path.isfile(dest):
            if dest not in anchor_cache:
                anchor_cache[dest] = heading_anchors(dest)
            if frag.lower() not in anchor_cache[dest]:
                errs.append(f"{rel}:{lineno}: broken anchor — no heading "
                            f"in {os.path.relpath(dest, REPO)!r} slugs to "
                            f"#{frag}")
    return errs


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    files = argv or [os.path.join(REPO, "README.md")] + sorted(
        glob.glob(os.path.join(REPO, "docs", "*.md")))
    errs: list = []
    cache: dict = {}
    for path in files:
        if not os.path.exists(path):
            errs.append(f"{os.path.relpath(path, REPO)}: missing")
            continue
        errs.extend(check_file(path, cache))
    for e in errs:
        print(f"DOCS ERROR: {e}", file=sys.stderr)
    if not errs:
        print(f"docs OK: {len(files)} files, all relative links and "
              f"anchors resolve")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
